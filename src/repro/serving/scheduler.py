"""Continuous-batching serving front end (docs/SERVING.md, "The serving
loop"): a bounded request queue + deadline-aware scheduler that packs
ragged live arrivals into the fixed-shape micro-batches ``search_batch``
serves.

The paper's headline system claim (§6.3) is thousands of *concurrent*
real-time inserts, deletes and searches per second; ``search_batch`` +
``SystemConfig.batch_queries`` give the fixed-shape micro-batch engine, and
this module is the piece that coalesces live traffic into it — the
continuous-batching pattern from LLM serving applied to the unified §5.2
fan-out.  A batch closes when it fills to ``batch_queries`` OR when the
oldest queued request's deadline budget (``SystemConfig.slo_ms`` minus a
measured dispatch estimate) would otherwise be violated, whichever comes
first; results are de-interleaved back to callers row by row, bit-identical
to calling ``search_batch`` directly (the batch IS one ``search_batch``
call, and per-query bit-parity is the serving engine's standing contract).

Determinism is a design seam, not an afterthought: every policy decision —
admit vs shed, close vs wait, miss vs meet — consults ONLY the injected
``Clock`` (``SystemConfig.clock``), never the wall.  The policy core
(``submit`` / ``poll`` / ``dispatch`` / ``next_close_time``) is fully
synchronous, so a test driving it with a ``VirtualClock`` reproduces every
decision bit-for-bit (``tests/test_scheduler.py``); ``start()`` wraps the
same core in a worker thread against the wall clock for production use,
where background threshold merges (``SystemConfig.background_merge``)
overlap the serving loop by construction (the merge thread swaps immutable
generations; searches never block on it).

Queue growth under overload is bounded: submissions past
``SystemConfig.serve_queue_capacity`` are SHED — ``submit`` returns None
and ``SystemStats.shed_requests`` counts them — so saturation surfaces as
explicit rejections instead of unbounded latency.

Filtered and multi-tenant traffic rides the same queue: ``submit`` takes an
optional ``FilterSpec`` and a closed micro-batch contains only tickets that
share the OLDEST queued ticket's spec (a batch is ONE ``search_batch``
call, and the filter is a per-call argument — mixing specs in one batch is
impossible, so the scheduler de-interleaves them while preserving per-spec
FIFO order).  When ``SystemConfig.tenant_quota`` > 0, a tenant may hold at
most that many queued tickets; submissions past the quota are shed with
the same explicit contract (``submit`` returns None) and counted per
tenant in ``SystemStats.tenant_sheds`` as well as ``shed_requests`` — one
tenant's burst cannot crowd every other tenant out of the bounded queue.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class Clock(Protocol):
    """What the scheduler needs from time: a monotonic ``now()``."""

    def now(self) -> float:
        ...


class WallClock:
    """Production clock: ``time.monotonic`` seconds."""

    def now(self) -> float:
        return time.monotonic()


class VirtualClock:
    """A manually-advanced clock: ``now()`` returns exactly what the test
    set, so every scheduler decision derived from it is deterministic.
    Picklable (it rides inside ``SystemConfig``)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, seconds: float) -> float:
        if seconds < 0:
            raise ValueError(f"VirtualClock.advance({seconds}): time only "
                             f"moves forward")
        self._t += float(seconds)
        return self._t


class Ticket:
    """One in-flight request: the caller's handle to its (ids, dists) row.

    ``result()`` blocks (wall-clock deployments); under a virtual clock the
    test drives the scheduler itself, so ``done`` is already set when it
    reads the fields.  ``latency`` is completion - arrival on the
    scheduler's clock; ``missed`` is the deadline verdict recorded at
    completion."""

    __slots__ = ("query", "arrival", "deadline", "fspec", "ids", "dists",
                 "completion", "missed", "done")

    def __init__(self, query: np.ndarray, arrival: float, deadline: float,
                 fspec=None):
        self.query = query
        self.arrival = arrival
        self.deadline = deadline
        self.fspec = fspec
        self.ids: Optional[np.ndarray] = None
        self.dists: Optional[np.ndarray] = None
        self.completion: Optional[float] = None
        self.missed = False
        self.done = threading.Event()

    @property
    def latency(self) -> Optional[float]:
        if self.completion is None:
            return None
        return self.completion - self.arrival

    def result(self, timeout: Optional[float] = None
               ) -> tuple[np.ndarray, np.ndarray]:
        if not self.done.wait(timeout):
            raise TimeoutError("request not served within timeout")
        return self.ids, self.dists


class BatchScheduler:
    """The deadline-aware continuous-batching scheduler.

    Policy (all against ``clock.now()``):

      * ``submit(query)`` — admit to the FIFO queue, or SHED (return None)
        when the queue is at ``cfg.serve_queue_capacity``.
      * ``poll()`` — close a micro-batch when (a) the queue holds
        ``cfg.batch_queries`` requests (full close), or (b) ``cfg.slo_ms``
        is set and ``now + dispatch_estimate`` has reached the OLDEST
        request's deadline (deadline close: waiting any longer would blow
        its budget).  An empty queue never closes a batch.
      * ``dispatch(batch)`` — one ``serve`` call on the stacked queries
        (default ``system.search_batch``; a ``ReplicaSet.route`` plugs in
        here for multi-replica serving), rows de-interleaved back to the
        tickets in arrival order, per-request latency recorded into
        ``stats.serve_latency`` and late completions into
        ``stats.deadline_misses``.

    The dispatch estimate is an EWMA of measured dispatch wall time on the
    scheduler's clock, seeded by ``cfg.dispatch_estimate_ms``; under a
    virtual clock the measurement is whatever the test advances (usually
    0), so the estimate — and hence every close decision — stays
    deterministic.

    ``run_once``/``flush`` drive the core synchronously; ``start``/``stop``
    run it on a worker thread (wall-clock deployments only — a virtual
    clock never moves on its own, so the thread would sleep forever).
    """

    def __init__(self, system, k: int, *, L: Optional[int] = None,
                 beam_width: Optional[int] = None,
                 serve: Optional[Callable] = None,
                 clock: Optional[Clock] = None):
        cfg = system.cfg
        if cfg.batch_queries <= 0:
            raise ValueError(
                "BatchScheduler needs SystemConfig.batch_queries > 0 — the "
                "micro-batch width is the shape batches are packed to")
        self.system = system
        self.stats = system.stats
        self.k = k
        self.L = L
        self.beam_width = beam_width
        self.batch_queries = cfg.batch_queries
        self.capacity = cfg.serve_queue_capacity
        self.slo = cfg.slo_ms / 1e3 if cfg.slo_ms > 0 else None
        self.tenant_quota = max(cfg.tenant_quota, 0)
        self._queued_by_tenant: dict = {}
        self.clock: Clock = clock or cfg.clock or WallClock()
        self.dispatch_estimate = max(cfg.dispatch_estimate_ms, 0.0) / 1e3
        self._serve = serve or system.search_batch
        self._queue: deque[Ticket] = deque()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._running = False
        # Occupancy accounting beyond the last-batch gauge: mean fill over
        # the scheduler's lifetime (benchmarks report it per run).
        self._occupancy_sum = 0.0
        self._batches = 0

    # ------------------------------------------------------------- requests
    def submit(self, query: np.ndarray, filter=None) -> Optional[Ticket]:
        """Admit one query (shape [dim]) or shed it.

        ``filter`` is an optional ``FilterSpec`` carried on the ticket and
        applied to the micro-batch that serves it.  Returns the caller's
        ``Ticket``, or None when the bounded queue is full OR the ticket's
        tenant already holds ``cfg.tenant_quota`` queued tickets — every
        shed is counted (``shed_requests``; quota sheds additionally in
        ``tenant_sheds[tenant]``), never silently dropped."""
        q = np.asarray(query, np.float32)
        fspec = filter if filter is not None and not filter.is_empty \
            else None
        tenant = fspec.tenant if fspec is not None else None
        with self._cond:
            if len(self._queue) >= self.capacity:
                self.stats.shed_requests += 1
                return None
            if (self.tenant_quota and tenant is not None
                    and self._queued_by_tenant.get(tenant, 0)
                    >= self.tenant_quota):
                self.stats.shed_requests += 1
                self.stats.tenant_sheds[tenant] = (
                    self.stats.tenant_sheds.get(tenant, 0) + 1)
                return None
            now = self.clock.now()
            deadline = now + self.slo if self.slo is not None else np.inf
            t = Ticket(q, now, deadline, fspec)
            if tenant is not None:
                self._queued_by_tenant[tenant] = (
                    self._queued_by_tenant.get(tenant, 0) + 1)
            self._queue.append(t)
            self.stats.scheduled_requests += 1
            self.stats.queue_depth = len(self._queue)
            self._cond.notify()
        return t

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    @property
    def mean_occupancy(self) -> float:
        """Lifetime mean fill fraction of dispatched micro-batches."""
        if self._batches == 0:
            return 0.0
        return self._occupancy_sum / self._batches

    # --------------------------------------------------------------- policy
    def next_close_time(self) -> Optional[float]:
        """The clock time at which the current queue must close: now when
        already full, the oldest deadline minus the dispatch estimate under
        an SLO, None when empty (or when no SLO bounds a partial batch —
        it then closes only on fill or ``flush``).  The worker thread (and
        a deterministic test driver) sleeps exactly until this."""
        with self._lock:
            return self._next_close_locked()

    def _next_close_locked(self) -> Optional[float]:
        if not self._queue:
            return None
        if len(self._queue) >= self.batch_queries:
            return self.clock.now()
        if self.slo is None:
            return None
        return self._queue[0].deadline - self.dispatch_estimate

    def poll(self) -> Optional[list[Ticket]]:
        """Close a micro-batch if policy says so at ``clock.now()``; the
        caller dispatches it.  Returns None when no close is due."""
        with self._lock:
            close_at = self._next_close_locked()
            if close_at is None or self.clock.now() < close_at:
                return None
            return self._take_locked()

    def _take_locked(self) -> list[Ticket]:
        """Pop the next micro-batch: up to ``batch_queries`` tickets that
        share the OLDEST queued ticket's filter spec, in FIFO order.  A
        batch is one ``search_batch`` call and the filter is a per-call
        argument, so mixed-spec arrivals de-interleave into same-spec
        batches; tickets with other specs keep their queue positions."""
        if not self._queue:
            return []
        spec = self._queue[0].fspec
        batch: list[Ticket] = []
        rest: list[Ticket] = []
        while self._queue and len(batch) < self.batch_queries:
            t = self._queue.popleft()
            if t.fspec == spec:
                batch.append(t)
            else:
                rest.append(t)
        for t in reversed(rest):
            self._queue.appendleft(t)
        for t in batch:
            if t.fspec is not None and t.fspec.tenant is not None:
                left = self._queued_by_tenant.get(t.fspec.tenant, 0) - 1
                if left > 0:
                    self._queued_by_tenant[t.fspec.tenant] = left
                else:
                    self._queued_by_tenant.pop(t.fspec.tenant, None)
        self.stats.queue_depth = len(self._queue)
        return batch

    # ------------------------------------------------------------- dispatch
    def dispatch(self, batch: list[Ticket]) -> None:
        """Serve one closed micro-batch and de-interleave the rows back.

        The batch rides ONE ``serve`` call on the stacked queries — with
        ``batch_queries`` set on the system, a partial batch is zero-padded
        by ``search_batch`` itself, so scheduled results are bit-identical
        to the caller invoking ``search_batch`` directly (per-query
        bit-parity is the engine's contract; this layer only stacks and
        slices rows in arrival order)."""
        if not batch:
            return
        qs = np.stack([t.query for t in batch])
        t0 = self.clock.now()
        kw = {}
        if batch[0].fspec is not None:
            # Filter rides as a kwarg only when set, so label-free serve
            # callables (and pre-filter test doubles) keep their signature.
            kw["filter"] = batch[0].fspec
        ids, dists = self._serve(qs, self.k, L=self.L,
                                 beam_width=self.beam_width, **kw)
        t1 = self.clock.now()
        # EWMA toward the measured dispatch; on a virtual clock the
        # measurement is the test's advance (0 unless it models compute),
        # so the estimate trajectory is deterministic too.
        self.dispatch_estimate = (0.8 * self.dispatch_estimate
                                  + 0.2 * (t1 - t0))
        occupancy = len(batch) / self.batch_queries
        self.stats.batches_dispatched += 1
        self.stats.batch_occupancy = occupancy
        self._occupancy_sum += occupancy
        self._batches += 1
        for i, t in enumerate(batch):
            t.ids, t.dists = ids[i], dists[i]
            t.completion = t1
            self.stats.serve_latency.record(t1 - t.arrival)
            if t1 > t.deadline:
                t.missed = True
                self.stats.deadline_misses += 1
            t.done.set()

    def run_once(self) -> int:
        """One synchronous scheduler turn: poll, dispatch if a batch
        closed.  Returns the number of requests served (0 = nothing due).
        This is the deterministic drive path — tests advance the virtual
        clock and call this at the times ``next_close_time`` names."""
        batch = self.poll()
        if batch is None:
            return 0
        self.dispatch(batch)
        return len(batch)

    def flush(self) -> int:
        """Drain the queue unconditionally (shutdown path): close batches
        of at most ``batch_queries`` until empty, deadlines or not."""
        served = 0
        while True:
            with self._lock:
                batch = self._take_locked()
            if not batch:
                return served
            self.dispatch(batch)
            served += len(batch)

    # ------------------------------------------------------- threaded loop
    def start(self) -> None:
        """Run the loop on a worker thread (wall-clock only): wake on
        arrivals, sleep until ``next_close_time``, dispatch outside the
        lock so submissions never block on a device program."""
        if self._thread and self._thread.is_alive():
            return
        self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self, flush: bool = True) -> None:
        """Stop the worker; by default serve whatever is still queued."""
        self._running = False
        with self._cond:
            self._cond.notify_all()
        if self._thread:
            self._thread.join()
            self._thread = None
        if flush:
            self.flush()

    def _loop(self) -> None:
        while True:
            with self._cond:
                if not self._running:
                    return
                close_at = self._next_close_locked()
                now = self.clock.now()
                if close_at is None:
                    self._cond.wait(timeout=0.05)
                    continue
                if now < close_at:
                    # New arrivals can only move the close EARLIER (a full
                    # queue) — the notify wakes us to re-evaluate.
                    self._cond.wait(timeout=close_at - now)
                    continue
                batch = self._take_locked()
            self.dispatch(batch)       # outside the lock: submits proceed
