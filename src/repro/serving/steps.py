"""Serve-step factories (prefill / decode / recsys scoring / retrieval).

Decode steps take and return KV caches so the launch layer can donate the
cache buffers (in-place update on device, no copy per token).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models import recsys as rec
from ..models import transformer as tf


def make_lm_prefill_step(cfg: tf.TransformerConfig) -> Callable:
    def prefill(params, tokens):
        logits, _, caches = tf.forward(params, tokens, cfg,
                                       collect_cache=True, last_only=True)
        return logits[:, -1], caches

    return prefill


def make_lm_decode_step(cfg: tf.TransformerConfig) -> Callable:
    def decode(params, caches, tokens, pos):
        logits, new_caches = tf.decode_step(params, caches, tokens, pos, cfg)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_caches

    return decode


def make_recsys_serve_step(cfg: rec.RecsysConfig) -> Callable:
    def serve(params, ids):
        return jax.nn.sigmoid(rec.recsys_forward(params, ids, cfg))

    return serve


def make_retrieval_step(cfg: rec.RecsysConfig, k: int = 100) -> Callable:
    """Exact candidate-scoring baseline for the retrieval_cand shape.

    For FM-family models the query embedding is the summed field embedding
    (the factorized part); items are rows of a candidate table.  The ANN
    path swaps this for a FreshDiskANN search (examples/sasrec_retrieval.py).
    """
    def retrieve(params, user_ids, item_table):
        if cfg.kind == "sasrec":
            q = rec.sasrec_user_embedding(params, user_ids, cfg)
        else:
            emb = rec.field_lookup(params["V"], user_ids, cfg)
            q = emb.sum(axis=-2)
        return rec.retrieval_topk(q, item_table, k)

    return retrieve
