"""Serve-step factories: LM prefill/decode, recsys scoring, retrieval — and
the ANN serving engine's mesh-sharded LTI lane (docs/SERVING.md).

Decode steps take and return KV caches so the launch layer can donate the
cache buffers (in-place update on device, no copy per token).

The ANN half implements ``SystemConfig.shard_lti``: the LTI's per-point
arrays (vectors, adjacency, PQ codes, flags) are row-partitioned over a
1-axis ``data`` mesh (``graph.shard_lti`` + ``distributed.sharding``), and
``make_sharded_unified_step`` builds the ONE jitted program that serves a
query batch against it — temp lanes replicated, the LTI lane under
``shard_map``.  Inside the lane the beam-search *state* (candidate list,
frontier, visited set) is replicated on every shard while each row access
is **owner-computed**: the shard owning a slot contributes the gathered
adjacency row / navigability flag / distance, all others contribute the
additive identity, and one ``psum`` recombines — so every shard steps the
identical beam loop and the lane is bit-identical to the single-device
lane for ANY shard count (the invariance contract
``tests/test_serving.py`` enforces on 1/2/4 fake CPU devices).

``make_disk_lti_lane`` is the storage-tier sibling: the same LTI lane with
its adjacency rows streamed from a decoupled on-disk layout through the
block cache + async prefetch pipeline (``repro.storage``, docs/STORAGE.md).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core import index as mem
from ..core import pq as pqm
from ..core.config import IndexConfig
from ..core.distance import INVALID, l2_sq
from ..core.graph import LaneStack
from ..core.search import batch_distances, beam_search, topk_masked
from ..distributed.ctx import shard_map_compat
from ..distributed.sharding import lti_lane_specs
from ..models import recsys as rec
from ..models import transformer as tf


def make_lm_prefill_step(cfg: tf.TransformerConfig) -> Callable:
    def prefill(params, tokens):
        logits, _, caches = tf.forward(params, tokens, cfg,
                                       collect_cache=True, last_only=True)
        return logits[:, -1], caches

    return prefill


def make_lm_decode_step(cfg: tf.TransformerConfig) -> Callable:
    def decode(params, caches, tokens, pos):
        logits, new_caches = tf.decode_step(params, caches, tokens, pos, cfg)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_caches

    return decode


def make_recsys_serve_step(cfg: rec.RecsysConfig) -> Callable:
    def serve(params, ids):
        return jax.nn.sigmoid(rec.recsys_forward(params, ids, cfg))

    return serve


# ---------------------------------------------------------------------------
# ANN: the mesh-sharded LTI lane (owner-computes row access, psum combine).
# ---------------------------------------------------------------------------

def _owned(ids: jax.Array, offset, n_local: int):
    """(owned mask, clipped local index) for global slot ids on this shard."""
    loc = ids - offset
    own = (ids >= 0) & (loc >= 0) & (loc < n_local)
    return own, jnp.clip(loc, 0, n_local - 1)


def shard_gather_mask(local_mask: jax.Array, ids: jax.Array, offset,
                      axis: str) -> jax.Array:
    """Gather a row-sharded bool array at global ids: owner contributes its
    flag, everyone else 0, one psum recombines.  ids < 0 -> False (exactly
    the dense ``(ids >= 0) & mask[max(ids, 0)]``)."""
    own, loc = _owned(ids, offset, local_mask.shape[0])
    hit = jnp.where(own, local_mask[loc].astype(jnp.int32), 0)
    return jax.lax.psum(hit, axis) > 0


class ShardedRows:
    """Owner-computes ``search.GraphSource`` over row-sharded graph arrays.

    Bit-parity with ``search.DenseSource``: the owner contributes exactly
    the dense gather's value and every other shard the additive identity,
    so the psum reproduces the unsharded result bit-for-bit (integer adds
    are exact; ids with no owner — INVALID frontier slots — sum to the
    INVALID row, matching the dense path's explicit mask).
    """

    def __init__(self, adjacency: jax.Array, active: jax.Array, offset,
                 axis: str):
        self.adjacency = adjacency          # [n_local, R]
        self.active = active                # [n_local]
        self.offset = offset
        self.axis = axis

    def rows(self, ids: jax.Array) -> jax.Array:
        own, loc = _owned(ids, self.offset, self.active.shape[0])
        contrib = jnp.where(own[:, None], self.adjacency[loc] - INVALID, 0)
        return jax.lax.psum(contrib, self.axis) + INVALID

    def node_ok(self, ids: jax.Array) -> jax.Array:
        return shard_gather_mask(self.active, ids, self.offset, self.axis)


class ShardedADC:
    """Owner-computes PQ asymmetric distances (the sharded ``PQBackend``).

    The owner evaluates ``pq.adc`` on its local code rows — the same
    arithmetic, hence the same f32 bits, as the dense ``adc_gather`` — and
    the psum adds exact zeros from every other shard (x + 0.0 == x for the
    non-negative finite distances ADC produces).
    """

    def __init__(self, codes: jax.Array, codebook: jax.Array, offset,
                 axis: str):
        self.codes = codes                  # [n_local, m] uint8
        self.codebook = codebook            # [m, ksub, dsub] f32 (replicated)
        self.offset = offset
        self.axis = axis

    def prepare(self, query: jax.Array) -> jax.Array:
        return pqm.lut(pqm.PQCodebook(self.codebook), query)

    def distances(self, ctx: jax.Array, ids: jax.Array, *,
                  use_kernel: bool = False) -> jax.Array:
        own, loc = _owned(ids, self.offset, self.codes.shape[0])
        d = pqm.adc(self.codes[loc], ctx)
        d = jax.lax.psum(jnp.where(own, d, 0.0), self.axis)
        return jnp.where(ids >= 0, d, jnp.inf)


class ShardedExact:
    """Owner-computes exact squared-L2 (the sharded ``FullPrecisionBackend``)
    — used for the LTI lane's in-program full-precision rerank, whose
    vector rows live sharded."""

    def __init__(self, vectors: jax.Array, offset, axis: str):
        self.vectors = vectors              # [n_local, d]
        self.offset = offset
        self.axis = axis

    def prepare(self, query: jax.Array) -> jax.Array:
        return query.astype(jnp.float32)

    def distances(self, ctx: jax.Array, ids: jax.Array, *,
                  use_kernel: bool = False) -> jax.Array:
        own, loc = _owned(ids, self.offset, self.vectors.shape[0])
        d = l2_sq(ctx[None, :], self.vectors[loc])
        d = jax.lax.psum(jnp.where(own, d, 0.0), self.axis)
        return jnp.where(ids >= 0, d, jnp.inf)


def make_sharded_lti_lane(mesh, cfg: IndexConfig, *, k_lane: int, L: int,
                          beam_width: Optional[int] = None,
                          rerank: bool = True, axis: str = "data"):
    """The LTI lane as a ``shard_map``: PQ-navigated beam search + exact
    rerank + per-lane top-k over row-sharded LTI arrays.

    Returns a callable ``(graph, codes, codebook, queries) -> (slot_ids
    [B, k_lane], dists, hops [B], cmps [B])`` whose outputs are replicated
    and bit-identical to the unsharded lane of ``index.search_lanes`` —
    counters included — for any shard count.  The lane runs the jnp engine
    path (``use_kernel=False``); the Pallas kernels are bit-identical to it
    by the docs/KERNELS.md contract, so parity with a kernel-routed
    unsharded lane still holds.
    """
    W = beam_width or cfg.beam_width
    gspecs, cspec = lti_lane_specs(axis)

    def local(g, codes, codebook, queries):
        n_local = g.active.shape[0]
        offset = jax.lax.axis_index(axis).astype(jnp.int32) * n_local
        src = ShardedRows(g.adjacency, g.active, offset, axis)
        res = beam_search(g.adjacency, g.active, g.start, queries,
                          ShardedADC(codes, codebook, offset, axis),
                          L=L, max_visits=cfg.visits_bound(L),
                          beam_width=W, use_kernel=False, source=src)
        ok = shard_gather_mask(g.active & ~g.deleted, res.ids, offset, axis)
        dists = res.dists
        if rerank:
            # DeleteList members masked BEFORE the gather (the
            # ``rerank_candidates`` contract), on the precomputed ok mask.
            dists = batch_distances(
                ShardedExact(g.vectors, offset, axis), queries,
                jnp.where(ok, res.ids, INVALID))
        ids, d = topk_masked(res.ids, dists, ok, k_lane)
        return ids, d, res.n_hops, res.n_cmps

    return shard_map_compat(local, mesh=mesh,
                            in_specs=(gspecs, cspec, P(), P()),
                            out_specs=(P(), P(), P(), P()),
                            check_vma=False)


def make_sharded_unified_step(mesh, cfg: IndexConfig, *, k: int, k_lane: int,
                              L: int, beam_width: Optional[int] = None,
                              rerank: bool = True,
                              axis: str = "data") -> Callable:
    """The unified §5.2 fan-out with the LTI lane mesh-sharded — still ONE
    jitted device program per query batch.

    Mirrors ``index.unified_search`` exactly (temp lanes vmapped at temp
    capacity, per-group slot->ext mapping, DeleteList filter, cross-tier
    dedupe/top-k), with the LTI lane dispatched through
    ``make_sharded_lti_lane``.  The returned step takes
    ``(stack, t_tabs, l_tab, t_drop, l_drop, queries)`` where
    ``stack.lti``/``stack.codes`` hold the ``graph.shard_lti`` layout; its
    (ids, dists) are bit-identical to the unsharded program's.
    """
    lane = make_sharded_lti_lane(mesh, cfg, k_lane=k_lane, L=L,
                                 beam_width=beam_width, rerank=rerank,
                                 axis=axis)

    @jax.jit
    def step(stack: LaneStack, t_tabs, l_tab, t_drop, l_drop, queries):
        B = queries.shape[0]
        parts_i, parts_d, hops, cmps = [], [], [], []
        if stack.temps is not None:
            tids, td, th, tc = mem.search_lanes(
                LaneStack(stack.temps, None, None, None), queries, cfg,
                k=k_lane, L=L, beam_width=beam_width)
            ext, dd = mem.lanes_to_ext(t_tabs, t_drop, tids, td)
            parts_i.append(jnp.transpose(ext, (1, 0, 2)).reshape(B, -1))
            parts_d.append(jnp.transpose(dd, (1, 0, 2)).reshape(B, -1))
            hops.append(th)
            cmps.append(tc)
        lids, ld, lh, lc = lane(stack.lti, stack.codes, stack.codebook,
                                queries)
        ext, dd = mem.lanes_to_ext(l_tab[None], l_drop[None],
                                   lids[None], ld[None])
        parts_i.append(ext[0])
        parts_d.append(dd[0])
        hops.append(lh[None])
        cmps.append(lc[None])
        mi, md = mem.fanout_merge(jnp.concatenate(parts_i, axis=1),
                                  jnp.concatenate(parts_d, axis=1), k=k)
        return mi, md, jnp.concatenate(hops), jnp.concatenate(cmps)

    return step


def make_disk_lti_lane(layout, cfg: IndexConfig, *, k_lane: int, L: int,
                       beam_width: Optional[int] = None, rerank: bool = True,
                       cache_mb: int = 0, prefetch_depth: int = 1,
                       latency_us: float = 0.0) -> Callable:
    """The LTI lane served off a decoupled on-disk layout (docs/STORAGE.md):
    PQ navigation on in-memory codes, adjacency rows streamed from
    ``topology.bin`` through the block cache + async prefetch pipeline, and
    the exact rerank gathered from ``data.bin``.

    Returns a callable ``(queries) -> (slot_ids [B, k_lane], dists, hops,
    cmps, reads)`` — the sharded lane's tuple plus per-query disk reads.
    With the cache off its outputs are bit-identical to the in-memory lane
    at any prefetch depth.  The lane owns a ``DiskLTISearcher`` exposed as
    ``lane.searcher`` (IO stats via ``lane.searcher.stats``; call
    ``lane.close()`` to stop the prefetch thread).
    """
    from ..storage.source import DiskLTISearcher
    searcher = DiskLTISearcher(layout, cfg, cache_mb=cache_mb,
                               prefetch_depth=prefetch_depth,
                               latency_us=latency_us)
    W = beam_width or cfg.beam_width

    def lane(queries):
        return searcher.search(queries, k=k_lane, L=L, beam_width=W,
                               rerank=rerank)

    lane.searcher = searcher
    lane.close = searcher.close
    return lane


def make_retrieval_step(cfg: rec.RecsysConfig, k: int = 100) -> Callable:
    """Exact candidate-scoring baseline for the retrieval_cand shape.

    For FM-family models the query embedding is the summed field embedding
    (the factorized part); items are rows of a candidate table.  The ANN
    path swaps this for a FreshDiskANN search (examples/sasrec_retrieval.py).
    """
    def retrieve(params, user_ids, item_table):
        if cfg.kind == "sasrec":
            q = rec.sasrec_user_embedding(params, user_ids, cfg)
        else:
            emb = rec.field_lookup(params["V"], user_ids, cfg)
            q = emb.sum(axis=-2)
        return rec.retrieval_topk(q, item_table, k)

    return retrieve
