"""Multi-replica data-parallel serving (docs/SERVING.md, "Multi-replica
routing").

``ReplicaSet`` replicates the unified fan-out across device groups on a
2-axis ``[replica, data]`` mesh (``distributed.sharding.replica_mesh``):
each row of the grid is one replica serving whole micro-batches against a
full copy of the index, and the columns are the within-replica
``shard_lti`` row shards — the existing owner-computed + psum'd sharded
program (``serving.steps.make_sharded_unified_step``) runs unchanged on
each replica's 1-axis group mesh (``replica_groups``), so the two axes
compose instead of interacting.

Micro-batches are routed round-robin across replicas.  Every replica
serves from the SAME immutable lane bundle the system's own serving path
uses (``_lane_bundle`` — tier states are immutable values, swapped by
flush/rollover/merge), and the sharded lane is bit-identical to the
unsharded one by the PR-5 contract, so per-query results are bit-identical
across replica counts and to ``system.search_batch`` directly.  Placement
caches are keyed by LTI graph/codes identity exactly like the system's
single-mesh cache: a background merge swaps the LTI, every replica's next
dispatch misses its placement cache and re-places the new generation —
routing survives merges by the same mechanism that makes single-replica
serving survive them.

When fewer devices exist than ``replicas x shards`` the set DEGRADES
rather than raises — shards cap at the census, then replicas at
``census // shards`` (always >= 1) — so the same config runs on a laptop
and a pod (census-capping mirrors ``SystemConfig.shard_lti``).
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import numpy as np


class ReplicaSet:
    """Round-robin router over N data-parallel serving replicas.

    ``search_batch`` mirrors ``system.search_batch`` (same signature, same
    micro-batch chunking, bit-identical results), routing each fixed-shape
    micro-batch to the next replica; pass it to ``BatchScheduler`` as the
    ``serve`` callable to put the scheduler in front of the replicas.
    ``dispatches[r]`` counts the micro-batches each replica served (the
    round-robin accounting contract in ``tests/test_serving.py``).
    """

    def __init__(self, system, n_replicas: int, *,
                 n_shards: Optional[int] = None):
        from ..distributed.sharding import replica_groups, replica_mesh
        if n_replicas < 1:
            raise ValueError(f"ReplicaSet: n_replicas={n_replicas} must "
                             f"be >= 1")
        if n_shards is None:
            n_shards = max(1, system.cfg.shard_lti)
        ndev = len(jax.devices())
        self.n_shards = min(max(1, n_shards), ndev)
        self.n_replicas = max(1, min(n_replicas, ndev // self.n_shards))
        self.system = system
        self.mesh = replica_mesh(self.n_replicas, self.n_shards)
        self.groups = replica_groups(self.mesh)
        self.dispatches = [0] * self.n_replicas
        self._rr = 0
        # Per-replica program caches, mirroring ``system._sharded_program``:
        # placement keyed by LTI graph/codes identity (a merge swaps them
        # and misses), jitted step per static (k, kk, L, W, rerank).
        self._place: list = [None] * self.n_replicas
        self._steps: list = [dict() for _ in range(self.n_replicas)]

    # ---------------------------------------------------------------- route
    def search_batch(self, queries: np.ndarray, k: int,
                     L: Optional[int] = None,
                     beam_width: Optional[int] = None,
                     replica: Optional[int] = None,
                     filter=None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Serve a query batch through the replica set.

        Identical contract to ``system.search_batch`` — same L/W/kk
        resolution, same ``batch_queries`` fixed-shape chunking with a
        zero-padded tail, same ``filter`` (FilterSpec) semantics,
        bit-identical per-query results — except each micro-batch is
        dispatched to a replica: round-robin by default, or pinned with
        ``replica=r``."""
        sys_ = self.system
        sys_._flush_inserts()
        fspec = filter if filter is not None and not filter.is_empty \
            else None
        L = L or sys_.cfg.index.L_search
        if k > L:
            raise ValueError(
                f"search(k={k}, L={L}): k must be <= L — the candidate "
                f"list holds only L entries, so more than L results cannot "
                f"be returned; raise L or lower k")
        W = beam_width or sys_._beam_width(queries)
        kk = min(max(k * 2, k + 8), L)
        q = np.asarray(queries, np.float32)
        B = q.shape[0]
        sys_.stats.searches += B
        if fspec is not None:
            sys_.stats.filtered_searches += B
            if fspec.tenant is not None:
                sys_.stats.tenant_searches[fspec.tenant] = (
                    sys_.stats.tenant_searches.get(fspec.tenant, 0) + B)
        if B == 0:
            return (np.zeros((0, k), np.int64),
                    np.zeros((0, k), np.float32))
        bq = sys_.cfg.batch_queries
        if not bq or B <= bq:
            return self._dispatch_sliced(q, bq, k, kk, L, W, replica, fspec)
        outs = []
        for lo in range(0, B, bq):
            chunk = q[lo:lo + bq]
            outs.append(self._dispatch_sliced(chunk, bq, k, kk, L, W,
                                              replica, fspec))
        return (np.concatenate([o[0] for o in outs]),
                np.concatenate([o[1] for o in outs]))

    def _dispatch_sliced(self, chunk, bq, k, kk, L, W, replica, fspec=None):
        """Pad one chunk to the compiled width, dispatch, slice pads off."""
        n = len(chunk)
        if bq and n < bq:
            qp = np.zeros((bq, chunk.shape[1]), np.float32)
            qp[:n] = chunk
            chunk = qp
        ids, d = self._dispatch(chunk, k, kk, L, W, replica, fspec)
        return ids[:n], d[:n]

    def _next_replica(self) -> int:
        r = self._rr
        self._rr = (self._rr + 1) % self.n_replicas
        return r

    # ------------------------------------------------------------- dispatch
    def _dispatch(self, queries, k, kk, L, W, replica, fspec=None):
        """Serve ONE fixed-shape micro-batch on one replica's device group.

        Mirrors ``system._search_dispatch``: same lane capture, same
        bundle, same drop masks, same per-dispatch latency sample — only
        the mesh the sharded program runs on differs.  Falls back to the
        system's own dispatch when there is no LTI lane to place (the
        bundle-less warm-up regime, or ``batch_fanout=False``): the
        replica axis only exists once an LTI generation is live."""
        import jax.numpy as jnp
        sys_ = self.system
        r = replica if replica is not None else self._next_replica()
        if not 0 <= r < self.n_replicas:
            raise ValueError(f"replica={r} out of range "
                             f"[0, {self.n_replicas})")
        rw_t, ro_temps, lti_entry = sys_._capture_lanes()
        if rw_t is None and not ro_temps and lti_entry is None:
            return sys_._aggregate([], k, queries.shape[0])
        bundle = (sys_._lane_bundle(rw_t, ro_temps, lti_entry)
                  if sys_.cfg.batch_fanout else None)
        if bundle is None or lti_entry is None:
            self.dispatches[r] += 1     # routed, served on the system path
            return sys_._search_dispatch(queries, k, kk, L, W, fspec)
        key, stack, t_tabs, l_tab, tables_np, label_tabs = bundle
        if fspec is None:
            t_drop, l_drop = sys_._drop_mask(key, tables_np)
        else:
            t_drop, l_drop = sys_._filter_drop(key, tables_np, label_tabs,
                                               fspec)
        do_rerank = sys_.cfg.rerank
        step, sstack = self._replica_program(
            r, stack, k=k, kk=kk, L=L, W=W, rerank=do_rerank)
        t0 = time.perf_counter()
        ids, d, _, _ = step(sstack, t_tabs, l_tab, t_drop, l_drop,
                            jnp.asarray(queries, jnp.float32))
        out = (np.asarray(ids).astype(np.int64),
               np.asarray(d).astype(np.float32))
        sys_.stats.search_latency.record(time.perf_counter() - t0)
        sys_.stats.search_dispatches += 1
        self.dispatches[r] += 1
        return out

    def _replica_program(self, r, stack, *, k, kk, L, W, rerank):
        """(step, stack-with-placed-LTI) for replica ``r``'s group mesh.

        Cache discipline is ``system._sharded_program``'s, per replica:
        placement re-keys on LTI graph/codes identity (merge survival),
        steps on the static shape tuple."""
        from ..core.graph import LaneStack, shard_lti
        from .steps import make_sharded_unified_step
        mesh = self.groups[r]
        place = self._place[r]
        if (place is None or place[0] is not stack.lti
                or place[1] is not stack.codes):
            sg, sc = shard_lti(stack.lti, stack.codes, self.n_shards,
                               mesh=mesh)
            place = (stack.lti, stack.codes, sg, sc)
            self._place[r] = place
        key = (k, kk, L, W, rerank)
        step = self._steps[r].get(key)
        if step is None:
            step = make_sharded_unified_step(
                mesh, self.system.cfg.index, k=k, k_lane=kk, L=L,
                beam_width=W, rerank=rerank)
            self._steps[r][key] = step
        return step, LaneStack(stack.temps, place[2], place[3],
                               stack.codebook)
