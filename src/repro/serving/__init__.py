from .steps import (make_lm_prefill_step, make_lm_decode_step,
                    make_recsys_serve_step, make_retrieval_step,
                    make_sharded_unified_step)  # noqa: F401
from .scheduler import (BatchScheduler, Clock, Ticket, VirtualClock,
                        WallClock)  # noqa: F401
from .replica import ReplicaSet  # noqa: F401
