from .steps import (make_lm_prefill_step, make_lm_decode_step,
                    make_recsys_serve_step, make_retrieval_step)  # noqa: F401
